"""Recurrent mixers: mLSTM / sLSTM (xLSTM, arXiv:2405.04517) and RG-LRU
(RecurrentGemma / Griffin, arXiv:2402.19427).

TPU adaptation notes (DESIGN.md §2): training/prefill uses parallel forms
(chunkwise mLSTM with carried (C, n, m) state; associative-scan RG-LRU);
decode uses O(1) recurrent state updates.  sLSTM has no parallel form
(hidden-to-hidden recurrence) and is scanned over time — the xLSTM pattern
keeps sLSTM to 1-in-8 blocks so this stays cheap.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..sharding import Ax, shard_as
from .layers import causal_conv1d, conv1d_init, dense_init

# ---------------------------------------------------------------------------
# mLSTM — matrix-memory LSTM
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jax.Array  # (b, h, hd, hd) matrix memory
    n: jax.Array  # (b, h, hd) normalizer
    m: jax.Array  # (b, h) stabilizer (log-space)


def init_mlstm(key, cfg):
    d = cfg.d_model
    h = cfg.num_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    params = {
        "wq": dense_init(ks[0], d, h * hd, "embed", "heads")[0],
        "wk": dense_init(ks[1], d, h * hd, "embed", "heads")[0],
        "wv": dense_init(ks[2], d, h * hd, "embed", "heads")[0],
        "wo": dense_init(ks[3], h * hd, d, "heads", "embed")[0],
        "wi_gate": dense_init(ks[4], d, h, "embed", "heads")[0],
        "wf_gate": dense_init(ks[5], d, h, "embed", "heads")[0],
        "f_bias": jnp.full((h,), 3.0, jnp.float32),  # forget-open init
        "i_bias": jnp.zeros((h,), jnp.float32),
    }
    axes = {
        "wq": Ax("embed", "heads"), "wk": Ax("embed", "heads"),
        "wv": Ax("embed", "heads"), "wo": Ax("heads", "embed"),
        "wi_gate": Ax("embed", "heads"), "wf_gate": Ax("embed", "heads"),
        "f_bias": Ax("heads"), "i_bias": Ax("heads"),
    }
    return params, axes


def init_mlstm_state(cfg, batch: int, dtype=jnp.float32) -> MLSTMState:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    return MLSTMState(
        c=jnp.zeros((batch, h, hd, hd), dtype),
        n=jnp.zeros((batch, h, hd), dtype),
        m=jnp.full((batch, h), -1e30, dtype),
    )


def mlstm_state_specs(cfg, batch: int, dtype=jnp.float32) -> MLSTMState:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    sds = jax.ShapeDtypeStruct
    return MLSTMState(c=sds((batch, h, hd, hd), dtype),
                      n=sds((batch, h, hd), dtype),
                      m=sds((batch, h), dtype))


def _mlstm_proj(params, cfg, x):
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(b, s, h, hd) / (hd ** 0.5)
    k = (x @ params["wk"].astype(dt)).reshape(b, s, h, hd) / (hd ** 0.5)
    v = (x @ params["wv"].astype(dt)).reshape(b, s, h, hd)
    logi = (x.astype(jnp.float32) @ params["wi_gate"]) + params["i_bias"]
    logf = jax.nn.log_sigmoid(
        (x.astype(jnp.float32) @ params["wf_gate"]) + params["f_bias"])
    return q, k, v, logi, logf  # gates: (b, s, h) in log space


def mlstm_parallel(params, cfg, x, chunk: int = 256,
                   state: Optional[MLSTMState] = None):
    """Chunkwise-parallel mLSTM: intra-chunk quadratic + carried state.

    Memory O(s * chunk); exact (up to fp) match of the recurrent form.
    Returns (y, final_state).
    """
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q, k, v, logi, logf = _mlstm_proj(params, cfg, x)
    if state is None:
        state = init_mlstm_state(cfg, b)
    nchunk = (s + chunk - 1) // chunk
    pad = nchunk * chunk - s
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(a):
        return a.reshape((b, nchunk, chunk) + a.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, a.ndim + 1)))

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(logi), to_chunks(logf)

    def body(carry, inp):
        c, n, m = carry                      # (b,h,hd,hd), (b,h,hd), (b,h)
        qj, kj, vj, li, lf = inp             # (b,chunk,h,...)
        csum = jnp.cumsum(lf, axis=1)        # (b, chunk, h)
        total = csum[:, -1]                  # (b, h)
        # log decay from chunk start to position t (inclusive of f_t)
        # intra-chunk pair weights: D[t,s'] = csum[t]-csum[s'] + li[s']
        a_pair = (csum[:, :, None, :] - csum[:, None, :, :]
                  + li[:, None, :, :])       # (b, t, s', h)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        a_pair = jnp.where(tri[None, :, :, None], a_pair, -jnp.inf)
        # inter-chunk: contribution of carried state to position t
        a_carry = csum + m[:, None, :]       # (b, t, h)
        m_intra = a_pair.max(axis=2)         # (b, t, h)
        m_new_t = jnp.maximum(a_carry, m_intra)
        # stabilized weights
        w_pair = jnp.exp(a_pair - m_new_t[:, :, None, :])     # (b,t,s',h)
        w_carry = jnp.exp(a_carry - m_new_t)                   # (b,t,h)
        # scores
        sc = jnp.einsum("bthd,bshd->btsh", qj, kj).astype(jnp.float32)
        sc = sc * w_pair
        num_intra = jnp.einsum("btsh,bshd->bthd", sc.astype(qj.dtype), vj)
        den_intra = sc.astype(jnp.float32).sum(axis=2)           # (b,t,h)
        num_carry = jnp.einsum(
            "bthd,bhde->bthe", qj.astype(jnp.float32) * w_carry[..., None],
            c)
        den_carry = jnp.einsum(
            "bthd,bhd->bth", qj.astype(jnp.float32) * w_carry[..., None], n)
        # xLSTM normalizer: max(|q . n_cum|, exp(-m)) on the *signed* sum
        den = jnp.maximum(jnp.abs(den_intra + den_carry), jnp.exp(-m_new_t))
        y = (num_intra.astype(jnp.float32) + num_carry) / den[..., None]
        # ---- update carried state to end of chunk -----------------------
        m_end = jnp.maximum(total + m, (total[:, None] - csum + li).max(1))
        decay_c = jnp.exp(total + m - m_end)                   # (b, h)
        kw = jnp.exp(total[:, None] - csum + li - m_end[:, None])  # (b,t,h)
        c_new = c * decay_c[..., None, None] + jnp.einsum(
            "bthd,bthe->bhde", (kj.astype(jnp.float32) * kw[..., None]),
            vj.astype(jnp.float32))
        n_new = n * decay_c[..., None] + jnp.einsum(
            "bth,bthd->bhd", kw, kj.astype(jnp.float32))
        return (c_new, n_new, m_end), y.astype(x.dtype)

    (c, n, m), ys = jax.lax.scan(
        body, (state.c, state.n, state.m), (qc, kc, vc, lic, lfc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nchunk * chunk, h, hd)
    y = y[:, :s].reshape(b, s, h * hd)
    out = y @ params["wo"].astype(x.dtype)
    out = shard_as(out, "batch", "seq", "embed_act")
    return out, MLSTMState(c=c, n=n, m=m)


def mlstm_decode(params, cfg, x, state: MLSTMState):
    """One-token recurrent update (O(1) state)."""
    b, s, d = x.shape
    assert s == 1
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q, k, v, logi, logf = _mlstm_proj(params, cfg, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]          # (b, h, hd)
    li, lf = logi[:, 0], logf[:, 0]              # (b, h)
    m_new = jnp.maximum(lf + state.m, li)
    f = jnp.exp(lf + state.m - m_new)
    i = jnp.exp(li - m_new)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c = state.c * f[..., None, None] + i[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n = state.n * f[..., None] + i[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).astype(x.dtype).reshape(b, 1, h * hd)
    out = y @ params["wo"].astype(x.dtype)
    out = shard_as(out, "batch", "seq", "embed_act")
    return out, MLSTMState(c=c, n=n, m=m_new)


# ---------------------------------------------------------------------------
# sLSTM — scalar-memory LSTM with block-diagonal recurrence
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: jax.Array  # (b, d) cell
    n: jax.Array  # (b, d) normalizer
    h: jax.Array  # (b, d) hidden
    m: jax.Array  # (b, d) stabilizer


def init_slstm(key, cfg):
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 3)
    params = {
        # input projections for 4 gates (i, f, z, o)
        "w": dense_init(ks[0], d, 4 * d, "embed", "mlp")[0],
        # block-diagonal recurrent weights per head: (4, h, hd, hd)
        "r": jax.random.normal(ks[1], (4, h, hd, hd), jnp.float32)
        * (1.0 / hd) ** 0.5,
        "b": jnp.concatenate([
            jnp.zeros((d,), jnp.float32),           # i
            jnp.full((d,), 3.0, jnp.float32),       # f (open)
            jnp.zeros((2 * d,), jnp.float32),       # z, o
        ]),
    }
    axes = {"w": Ax("embed", "mlp"), "r": Ax(None, "heads", None, None),
            "b": Ax("mlp")}
    return params, axes


def init_slstm_state(cfg, batch: int, dtype=jnp.float32) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), dtype)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, dtype))


def slstm_state_specs(cfg, batch: int, dtype=jnp.float32) -> SLSTMState:
    d = cfg.d_model
    sds = jax.ShapeDtypeStruct
    return SLSTMState(c=sds((batch, d), dtype), n=sds((batch, d), dtype),
                      h=sds((batch, d), dtype), m=sds((batch, d), dtype))


def _slstm_step(params, cfg, state: SLSTMState, zx):
    """zx: (b, 4d) pre-activations from the input projection."""
    b = zx.shape[0]
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    hh = state.h.reshape(b, h, hd)
    rec = jnp.einsum("bhd,ghde->gbhe", hh.astype(jnp.float32), params["r"])
    rec = rec.reshape(4, b, d)
    z = zx.astype(jnp.float32).reshape(b, 4, d).transpose(1, 0, 2) + rec
    li = z[0]
    lf = jax.nn.log_sigmoid(z[1])
    cell_in = jnp.tanh(z[2])
    o = jax.nn.sigmoid(z[3])
    m_new = jnp.maximum(lf + state.m, li)
    f = jnp.exp(lf + state.m - m_new)
    i = jnp.exp(li - m_new)
    c = f * state.c + i * cell_in
    n = jnp.maximum(f * state.n + i, 1e-6)
    hnew = o * (c / n)
    return SLSTMState(c=c, n=n, h=hnew, m=m_new)


def slstm(params, cfg, x, state: Optional[SLSTMState] = None):
    """Sequential scan over time (no parallel form exists)."""
    b, s, d = x.shape
    if state is None:
        state = init_slstm_state(cfg, b)
    zx = x @ params["w"].astype(x.dtype) + params["b"].astype(x.dtype)

    def body(st, z_t):
        st2 = _slstm_step(params, cfg, st, z_t)
        return st2, st2.h

    final, hs = jax.lax.scan(body, state, zx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    return shard_as(y, "batch", "seq", "embed_act"), final


def slstm_decode(params, cfg, x, state: SLSTMState):
    b, s, d = x.shape
    assert s == 1
    zx = (x @ params["w"].astype(x.dtype) + params["b"].astype(x.dtype))[:, 0]
    st = _slstm_step(params, cfg, state, zx)
    return st.h[:, None, :].astype(x.dtype), st


# ---------------------------------------------------------------------------
# RG-LRU — real-gated linear recurrent unit (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------


class RGLRUState(NamedTuple):
    h: jax.Array          # (b, w) recurrent state
    conv: jax.Array       # (b, conv_width-1, w) conv tail


def init_rglru(key, cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    # a-parameter initialized so a ~ U(0.9, 0.999) at r=1
    lam = jnp.log(jnp.expm1(-jnp.log(
        jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)) / 8.0))
    params = {
        "wx": dense_init(ks[1], d, w, "embed", "lru")[0],
        "wgate": dense_init(ks[2], d, w, "embed", "lru")[0],
        "conv": conv1d_init(ks[3], cfg.conv_width, w)[0],
        "w_r": dense_init(ks[4], w, w, "lru", "lru")[0],
        "w_i": dense_init(ks[5], w, w, "lru", "lru")[0],
        "lam": lam,
        "wo": dense_init(jax.random.fold_in(key, 7), w, d, "lru", "embed")[0],
    }
    axes = {
        "wx": Ax("embed", "lru"), "wgate": Ax("embed", "lru"),
        "conv": Ax("conv", "lru"), "w_r": Ax("lru", "lru"),
        "w_i": Ax("lru", "lru"), "lam": Ax("lru"),
        "wo": Ax("lru", "embed"),
    }
    return params, axes


def init_rglru_state(cfg, batch: int, dtype=jnp.float32) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(h=jnp.zeros((batch, w), dtype),
                      conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype))


def rglru_state_specs(cfg, batch: int, dtype=jnp.float32) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    sds = jax.ShapeDtypeStruct
    return RGLRUState(h=sds((batch, w), dtype),
                      conv=sds((batch, cfg.conv_width - 1, w), dtype))


_LRU_C = 8.0


def _rglru_coeffs(params, u):
    """u: (b, s, w) conv output -> per-step (a, bx) of h = a*h + bx."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_r"])
    i = jax.nn.sigmoid(uf @ params["w_i"])
    log_a = -_LRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) multiplier keeps the state norm bounded
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, bx


def rglru(params, cfg, x, state: Optional[RGLRUState] = None):
    """Griffin recurrent block: gate branch * (conv -> RG-LRU) branch."""
    b, s, d = x.shape
    if state is None:
        state = init_rglru_state(cfg, b)
    dt = x.dtype
    gate = jax.nn.gelu((x @ params["wgate"].astype(dt)), approximate=True)
    u = x @ params["wx"].astype(dt)
    u, conv_state = causal_conv1d(u, params["conv"], state.conv
                                  if state.conv.shape[1] else None)
    a, bx = _rglru_coeffs(params, u)
    # associative linear recurrence h_t = a_t h_{t-1} + bx_t
    a0 = jnp.concatenate([jnp.ones((b, 1, a.shape[-1]), a.dtype), a], axis=1)
    b0 = jnp.concatenate([state.h[:, None, :].astype(bx.dtype), bx], axis=1)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, hs = jax.lax.associative_scan(comb, (a0, b0), axis=1)
    hs = hs[:, 1:]  # drop the injected initial state
    y = (hs.astype(dt) * gate) @ params["wo"].astype(dt)
    y = shard_as(y, "batch", "seq", "embed_act")
    return y, RGLRUState(h=hs[:, -1], conv=conv_state.astype(state.conv.dtype))


def rglru_decode(params, cfg, x, state: RGLRUState):
    b, s, d = x.shape
    assert s == 1
    dt = x.dtype
    gate = jax.nn.gelu((x @ params["wgate"].astype(dt)), approximate=True)
    u = x @ params["wx"].astype(dt)
    u, conv_state = causal_conv1d(u, params["conv"], state.conv)
    a, bx = _rglru_coeffs(params, u)
    h = a[:, 0] * state.h + bx[:, 0]
    y = (h[:, None, :].astype(dt) * gate) @ params["wo"].astype(dt)
    y = shard_as(y, "batch", "seq", "embed_act")
    return y, RGLRUState(h=h, conv=conv_state.astype(state.conv.dtype))
