"""Event-vs-batch engine timing on the adaptive campaign grid.

The paper's central empirical claim is about the adaptive techniques'
overhead/benefit trade-off — which makes AWF-B/C/D/E, AF/mAF, BOLD (and
worker-dependent WF2) the band a selection campaign sweeps hardest, and
(before the lockstep band) the only band still stepping the event oracle
one heapq event at a time.  This benchmark measures the same adaptive
technique x workload x chunk-param x repetition grid twice — once per
config through the discrete-event oracle, once through
``repro.core.simulate_batch``'s config-parallel lockstep band — verifies
bit-for-bit agreement AND that no config fell back to the oracle, and
records the wall-clock ratio under benchmarks/results/ so the perf
trajectory accumulates run over run.

    PYTHONPATH=src python -m benchmarks.adaptive_bench \
        [--quick] [--reps N] [--min-speedup X]

The grid uses timesteps=2 so the adaptive state genuinely carries across
instances (plain AWF only adapts at time-step boundaries), and a
repetition-seed axis mirroring the paper's statistical protocol — the
regime the engine is built for: the seed axis dedups (adaptive
techniques never read the seed) and the remaining lanes advance in
vectorized lockstep rounds.
"""

from __future__ import annotations

import argparse
import json
import platform
import time

from repro.core import (
    NOISY_PROFILE,
    batch_grid,
    dist_loop,
    gromacs_like,
    nab_like,
    simulate,
    simulate_batch,
    sphynx_like,
)

from .common import RESULTS

P = 20
TIMESTEPS = 2

#: the adaptive band: every technique the plan-precompute path cannot
#: cover (adaptive or worker-dependent), all carrying step_batch forms
ADAPTIVE_TECHS = ("awf", "awf_b", "awf_c", "awf_d", "awf_e", "af", "maf",
                  "bold", "wf2")


def campaign_grid(n: int = 100_000, reps: int = 10):
    """Adaptive-only campaign: band x 4 loop classes x 3 cps x reps
    (the multi-chunk-param sweep of the paper's Sec. 4 protocol)."""
    loops = [sphynx_like(n=n), gromacs_like(n=n),
             dist_loop("L1", n=max(n // 100, 100)), nab_like()]
    return batch_grid(ADAPTIVE_TECHS, loops, ps=(P,),
                      chunk_params=(None, 16, 64),
                      seeds=tuple(range(reps)),
                      chunk_cold_cost=2e-6, timesteps=TIMESTEPS)


def run(n: int = 100_000, reps: int = 10) -> dict:
    configs = campaign_grid(n=n, reps=reps)

    # warm both engines on a tiny grid so neither side pays the one-off
    # import/allocator cost inside its timed region
    warm = campaign_grid(n=500, reps=1)
    simulate_batch(warm, profile=NOISY_PROFILE)
    for c in warm:
        simulate(c.technique, c.workload, c.p, c.chunk_param, seed=c.seed,
                 timesteps=c.timesteps, chunk_cold_cost=c.chunk_cold_cost,
                 profile=NOISY_PROFILE)

    t0 = time.perf_counter()
    batch = simulate_batch(configs, profile=NOISY_PROFILE)
    t_batch = time.perf_counter() - t0

    t0 = time.perf_counter()
    event = [
        simulate(c.technique, c.workload, c.p, c.chunk_param, seed=c.seed,
                 timesteps=c.timesteps, chunk_cold_cost=c.chunk_cold_cost,
                 profile=NOISY_PROFILE)
        for c in configs
    ]
    t_event = time.perf_counter() - t0

    mismatches = sum(
        rb.record.t_par != re_.record.t_par
        for b, e in zip(batch, event) for rb, re_ in zip(b, e))
    # a SimResult off the lockstep band carries no live technique
    # instance — any non-None marks an event-oracle fallback
    oracle_fallbacks = sum(
        res.technique is not None for b in batch for res in b)
    return dict(
        name="adaptive_speedup/campaign",
        grid_configs=len(configs),
        techniques=len(ADAPTIVE_TECHS),
        workloads=4,
        chunk_params=3,
        reps=reps,
        timesteps=TIMESTEPS,
        n=n,
        p=P,
        t_event_s=round(t_event, 3),
        t_batch_s=round(t_batch, 3),
        speedup=round(t_event / t_batch, 1),
        agreement_mismatches=mismatches,
        oracle_fallbacks=oracle_fallbacks,
        python=platform.python_version(),
        machine=platform.machine(),
        timestamp=time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    )


def rows(n: int = 100_000, reps: int = 10) -> list[dict]:
    """benchmarks.run entry point (name,us_per_call,derived rows)."""
    r = run(n=n, reps=reps)
    r["us_per_call"] = r["t_batch_s"] * 1e6 / max(r["grid_configs"], 1)
    return [r]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small grid for CI (writes adaptive_quickbench"
                         ".json and gates on --min-speedup)")
    ap.add_argument("--reps", type=int, default=None,
                    help="repetitions per config (default 10, quick 4)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail unless batch/event speedup >= this "
                         "(default: 5.0 under --quick, no gate otherwise)")
    args = ap.parse_args()
    reps = args.reps if args.reps is not None else (4 if args.quick else 10)
    n = 20_000 if args.quick else 100_000
    floor = args.min_speedup
    if floor is None and args.quick:
        floor = 5.0
    result = run(n=n, reps=reps)
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / ("adaptive_quickbench.json" if args.quick
                     else "adaptive_speedup.json")
    history = []
    if out.exists():
        prev = json.loads(out.read_text())
        history = prev if isinstance(prev, list) else [prev]
    history.append(result)
    out.write_text(json.dumps(history, indent=1))
    print(json.dumps(result, indent=2))
    if result["agreement_mismatches"]:
        raise SystemExit("adaptive band disagrees with the event oracle")
    if result["oracle_fallbacks"]:
        raise SystemExit("adaptive configs fell back to the event oracle")
    if floor is not None and result["speedup"] < floor:
        raise SystemExit(
            f"adaptive-band speedup {result['speedup']}x is below the "
            f"{floor}x floor")


if __name__ == "__main__":
    main()
