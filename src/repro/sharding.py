"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Params and activations are annotated with *logical* axis names; a
`ShardingRules` table maps them to mesh axes.  `shard_as()` applies a
`with_sharding_constraint` when a rules context is active (under jit with a
mesh) and is a no-op otherwise, so model code is mesh-agnostic and runs
unsharded on one CPU device for smoke tests.

Default layout (see DESIGN.md §5):
    batch           -> (pod, data)      activations & KV cache
    heads/kv_heads  -> model            tensor parallel attention
    mlp / experts   -> model            tensor / expert parallel FFN
    vocab           -> model            sharded embedding + logits
    embed (params)  -> data             FSDP: fully-sharded parameters
Dims not divisible by their mesh axes fall back to replication.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "Ax",
    "ShardingRules",
    "DEFAULT_RULES",
    "use_rules",
    "current_rules",
    "shard_as",
    "logical_to_spec",
    "param_shardings",
]


class Ax:
    """Leaf wrapper for a tuple of logical axis names.  Deliberately NOT a
    pytree, so an axes tree mirrors a param tree with Ax leaves."""

    __slots__ = ("names",)

    def __init__(self, *names: Optional[str]):
        self.names = tuple(names)

    def __repr__(self):
        return f"Ax{self.names}"

    def __eq__(self, other):
        return isinstance(other, Ax) and self.names == other.names

    def __hash__(self):
        return hash(self.names)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis -> mesh axis (or tuple of mesh axes, or None)."""

    rules: tuple[tuple[str, object], ...]
    mesh: Optional[Mesh] = None

    def lookup(self, name: str):
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def replace(self, **updates) -> "ShardingRules":
        new = dict(self.rules)
        new.update(updates)
        return ShardingRules(rules=tuple(new.items()), mesh=self.mesh)

    def with_mesh(self, mesh: Mesh) -> "ShardingRules":
        return dataclasses.replace(self, mesh=mesh)


# Baseline rules for the (pod, data, model) production mesh.  The single-pod
# mesh simply has no 'pod' axis; GSPMD ignores absent axes when we filter.
DEFAULT_RULES = ShardingRules(rules=(
    ("batch", ("pod", "data")),
    ("seq", None),
    ("embed", "data"),        # FSDP param shard of d_model dims
    ("embed_act", None),      # activation d_model replicated across model
    ("heads", "model"),
    ("kv_heads", "model"),
    ("head_dim", None),
    ("mlp", "model"),
    ("experts", "model"),
    ("moe_group", ("pod", "data")),
    ("expert_mlp", None),
    ("vocab", "model"),
    ("lru", "model"),
    ("conv", None),
    ("capacity", None),
    ("capacity_shard", "model"),
    ("stack", None),          # scan-stacked layer dim
))

_ctx = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        s = 1
        for a in axis:
            s *= _axis_size(mesh, a)
        return s
    return mesh.shape[axis] if axis in mesh.shape else 1


def logical_to_spec(rules: ShardingRules, logical: Sequence[Optional[str]],
                    shape: Optional[Sequence[int]] = None) -> P:
    """Resolve logical axis names to a PartitionSpec.  If `shape` is given,
    dims not divisible by their mesh-axis size are replicated instead."""
    mesh = rules.mesh
    out = []
    used: set = set()
    for i, name in enumerate(logical):
        axis = rules.lookup(name) if name else None
        if axis is None:
            out.append(None)
            continue
        # drop mesh axes that don't exist in the current mesh
        if isinstance(axis, (tuple, list)):
            axis = tuple(a for a in axis
                         if mesh is None or a in mesh.shape) or None
            if axis is not None and len(axis) == 1:
                axis = axis[0]
        elif mesh is not None and axis not in mesh.shape:
            axis = None
        if axis is None:
            out.append(None)
            continue
        # no mesh axis may appear twice in one spec
        key = tuple(axis) if isinstance(axis, tuple) else (axis,)
        if used & set(key):
            out.append(None)
            continue
        if shape is not None and mesh is not None:
            if shape[i] % _axis_size(mesh, axis) != 0:
                out.append(None)
                continue
        used |= set(key)
        out.append(axis)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard_as(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axes (no-op without rules)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = logical_to_spec(rules, logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )


def param_shardings(rules: ShardingRules, params, axes):
    """NamedShardings for a param pytree given its logical-axes pytree
    (Ax leaves)."""
    mesh = rules.mesh
    assert mesh is not None

    def one(p, ax):
        assert isinstance(ax, Ax), f"axes tree leaf must be Ax, got {ax!r}"
        shape = p.shape if hasattr(p, "shape") else None
        return NamedSharding(mesh, logical_to_spec(rules, ax.names, shape))

    return jax.tree.map(one, params, axes)
