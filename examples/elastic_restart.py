"""Elastic restart demo: train, lose a "pod", restart on fewer workers.

Shows the full fault-tolerance path at laptop scale: checkpoints are
mesh-agnostic (logical arrays), the data pipeline is deterministic by
step, the DLS planner re-plans shares for the new worker count, and
adaptive techniques *inherit* their learned per-worker telemetry across
the shrink/grow (``Technique.inherit``) — the paper's self-scheduling
argument applied at pod scale.

``elastic_handoff`` is the re-plan + inherit path on its own (no jax,
no training loop) — it is what ``tests/test_elastic.py`` exercises.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import numpy as np

from repro.core import make_technique, plan_schedule, replan


def elastic_handoff(n: int = 1000, old_p: int = 4, new_p: int = 3,
                    technique: str = "awf_b", chunks_done: int = 10):
    """Re-plan ``n`` iterations from ``old_p`` onto ``new_p`` workers.

    Returns ``(new_plan, old_tech, new_tech)``: the re-balanced
    :class:`~repro.core.planner.Plan` over the surviving workers, and the
    adaptive technique pair after ``new_tech.inherit(old_tech)`` — the
    learned per-worker weights/telemetry of the workers that survive the
    resize carry over instead of restarting cold (new workers, on grow,
    start from a neutral prior).
    """
    # the chunk-plan view: re-balance the remaining iterations
    plan = plan_schedule("fac2", n=n, p=old_p)
    done = sum(c.size for c in plan.chunks[:chunks_done])
    # note: replan shifts chunk starts by `done` (they index the original
    # iteration space), so conservation is checked on sizes, not validate()
    new_plan = replan(plan, new_p=new_p, done_iterations=done)
    assert sum(c.size for c in new_plan.chunks) == n - done

    # the adaptive-state view: run the old technique for a few grants so
    # it learns per-worker speeds, then hand its state to the resized one
    old = make_technique(technique, n=n, p=old_p)
    old.begin_instance(0)
    speeds = 1.0 + 0.5 * np.arange(old_p)  # worker w takes 1 + w/2 ms/iter
    for i in range(4 * old_p):
        w = i % old_p
        g = old.next_chunk(w)
        if g is None:
            break
        old.complete_chunk(w, g, exec_time=g.size * speeds[w] * 1e-3,
                           sched_time=1e-6)
    new = make_technique(technique, n=n - done, p=new_p)
    new.inherit(old)
    new.begin_instance(1)
    return new_plan, old, new


def main():
    from repro.configs.base import ModelConfig
    from repro.data.pipeline import DataConfig
    from repro.optim.adamw import OptimizerConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(name="demo-20m", family="dense", num_layers=4,
                      d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
                      vocab_size=4096, tie_embeddings=True, remat="none")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                          global_batch=8, mean_doc_len=160.0)
    ckpt = "/tmp/repro_elastic_demo"

    # --- phase 1: "4-pod" run that dies at step 12 -------------------------
    print("=== phase 1: 4 worker groups, failure injected at step 12 ===")
    die = {12}

    def failure(step):
        if step in die:
            die.discard(step)
            raise RuntimeError("pod 3 lost (injected)")

    tr1 = Trainer(cfg, OptimizerConfig(learning_rate=1e-3, warmup_steps=2),
                  TrainerConfig(steps=16, checkpoint_every=4,
                                checkpoint_dir=ckpt, log_every=4,
                                num_worker_groups=4, max_failures=1),
                  data_cfg, failure_hook=failure)
    tr1.run()
    print(f"phase 1 checkpoints: {tr1.store.steps()}")

    # --- phase 2: restart with 3 worker groups (elastic shrink) ------------
    print("\n=== phase 2: restart from checkpoint with 3 worker groups ===")
    tr2 = Trainer(cfg, OptimizerConfig(learning_rate=1e-3, warmup_steps=2),
                  TrainerConfig(steps=24, checkpoint_every=8,
                                checkpoint_dir=ckpt, log_every=4,
                                num_worker_groups=3),
                  data_cfg)
    hist = tr2.run()
    print(f"resumed at step {hist[0]['step']}, finished at "
          f"{hist[-1]['step']}, final shares={hist[-1]['shares']}")

    # --- the DLS view: re-planning + adaptive-state handoff -----------------
    new_plan, old, new = elastic_handoff()
    loads = np.zeros(3)
    for c in new_plan.chunks:
        loads[c.worker] += c.size
    print(f"\nDLS replan: {new_plan.n} remaining iterations re-balanced "
          f"onto 3 workers -> loads {loads.astype(int).tolist()}")
    print(f"AWF-B handoff 4 -> 3 workers: old weights "
          f"{np.round(old.weights, 3).tolist()} -> inherited "
          f"{np.round(new.weights, 3).tolist()}")


if __name__ == "__main__":
    main()
