"""Two-level cluster scheduling: node-level DLS vs static replica
partitioning (the paper's cross-node / MPI+OpenMP finding, after
Mohammed et al., arXiv:1911.06714).

Runs `repro.serve.cluster.simulate_cluster_batch` grids over
(node-technique x traffic skew) with a fixed intra-node technique, plus
a degraded-replica scenario, and records per-scenario makespans,
latency percentiles and cross-node imbalance (`cov` /
`percent_imbalance` over per-replica busy time).

The claims this bench gates on (CI runs `--quick`):

  * on at least two skewed/bursty traffic scenarios, the best *dynamic*
    node-level technique beats static replica partitioning by >= 1.2x
    makespan, with cross-node percent-imbalance reduced;
  * on the uniform control, static stays within 5% of the best — node-
    level dynamics cost nothing when the traffic is already balanced.

`heavy_tail` carries a *tolerance band* instead of a win gate:
depending on n/seed its rare giants can each cost on the order of the
ideal makespan, in which case the critical path is one indivisible
request and binding it early (which static does by accident) is all
that matters — dynamic wins the milder draws and loses those.  Both
regimes occur at this bench's own parameters (the n=600 --quick draw is
a 1.4x dynamic win, the n=800 full draw a 0.95x loss), so the gate only
pins the ratio inside ``HEAVY_TAIL_BAND``: dynamic may trail static by
at most the one-giant margin and may not silently regress into a
blowout either way.

Writes benchmarks/results/cluster_balance.json (full run) or
cluster_balance_quick.json (--quick), so the CI gate never dirties the
committed full-run artifact.

    PYTHONPATH=src python -m benchmarks.cluster_balance [--quick]
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from repro.serve.cluster import cluster_grid, make_traffic, simulate_cluster_batch
from repro.trials.statistics import ToleranceBand

from .common import RESULTS

#: node-level schedules swept per scenario ("<node>/<thread>")
NODE_TECHNIQUES = ("static", "ss,4", "gss", "fac2", "awf_b")
THREAD_TECHNIQUE = "fac2"
#: scenarios where the paper's dynamic-beats-static claim is gated
GATED_SCENARIOS = ("spiky", "zipf", "bursty", "degraded_replica")
SPEEDUP_FLOOR = 1.2
#: heavy_tail tolerance band (see module docstring): static may win by
#: the indivisible-giant margin (lower edge), dynamic by an ordinary
#: rebalancing margin (upper edge) — measured 0.95x (full) / 1.4x
#: (--quick) at the committed parameters
HEAVY_TAIL_BAND = ToleranceBand(0.8, 3.0)
UNIFORM_SLACK = 1.05


def scenarios(quick: bool = False) -> dict[str, dict]:
    # the skewed scenarios need enough requests that no single giant is
    # the critical path (work per slot >> one giant's cost) — below
    # ~600 the spiky/zipf streams degenerate into the heavy_tail regime
    n = 600 if quick else 800
    out = {
        name: dict(requests=make_traffic(name, n=n, seed=1),
                   replica_speed=None)
        for name in ("uniform", "heavy_tail", "spiky", "zipf", "bursty")
    }
    # heterogeneous hardware: uniform traffic, one replica 2.5x slower —
    # the skew is in the nodes, not the requests
    out["degraded_replica"] = dict(
        requests=make_traffic("uniform", n=n, seed=2),
        replica_speed=[2.5] + [1.0] * 7)
    return out


def run(quick: bool = False, replicas: int = 8, workers: int = 4) -> dict:
    out: dict = dict(
        name="cluster_balance",
        replicas=replicas,
        workers_per_replica=workers,
        thread_technique=THREAD_TECHNIQUE,
        python=platform.python_version(),
        machine=platform.machine(),
        timestamp=time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        scenarios={},
    )
    dynamic_wins = []
    for name, sc in scenarios(quick=quick).items():
        configs = cluster_grid(
            [f"{t}/{THREAD_TECHNIQUE}" for t in NODE_TECHNIQUES],
            {name: sc["requests"]},
            num_replicas=replicas, workers_per_replica=workers,
            replica_speed=sc["replica_speed"])
        rows = {}
        for tech, r in zip(NODE_TECHNIQUES, simulate_cluster_batch(configs)):
            rows[tech] = dict(
                makespan=round(r["makespan"], 4),
                mean_latency=round(r["mean_latency"], 4),
                p99=round(r["p99"], 4),
                cross_node_cov=round(r["cross_node_cov"], 4),
                cross_node_pi=round(r["cross_node_pi"], 2),
                node_chunks=r["node_chunks"],
            )
        static = rows["static"]
        dynamic = {t: rows[t] for t in NODE_TECHNIQUES if t != "static"}
        best = min(dynamic, key=lambda t: dynamic[t]["makespan"])
        speedup = static["makespan"] / max(dynamic[best]["makespan"], 1e-12)
        pi_reduced = dynamic[best]["cross_node_pi"] < static["cross_node_pi"]
        out["scenarios"][name] = dict(
            n=len(sc["requests"]),
            replica_speed=sc["replica_speed"],
            techniques=rows,
            static_makespan=static["makespan"],
            best_dynamic=best,
            best_dynamic_makespan=dynamic[best]["makespan"],
            speedup_vs_static=round(speedup, 3),
            pi_reduced=bool(pi_reduced),
        )
        if (name in GATED_SCENARIOS and speedup >= SPEEDUP_FLOOR
                and pi_reduced):
            dynamic_wins.append(name)
    out["dynamic_wins"] = dynamic_wins
    u = out["scenarios"]["uniform"]
    best_any = min(r["makespan"] for r in u["techniques"].values())
    out["uniform_static_within"] = round(
        u["static_makespan"] / max(best_any, 1e-12), 4)
    return out


def check(result: dict) -> list[str]:
    """The bench's acceptance gates; returns failure messages."""
    fails = []
    if len(result["dynamic_wins"]) < 2:
        fails.append(
            f"dynamic node-level scheduling beat static by >= "
            f"{SPEEDUP_FLOOR}x (with p.i. reduced) on only "
            f"{result['dynamic_wins']} — need >= 2 skewed scenarios")
    if result["uniform_static_within"] > UNIFORM_SLACK:
        fails.append(
            f"static replica partitioning fell "
            f"{result['uniform_static_within']}x behind the best on the "
            f"uniform control (allowed {UNIFORM_SLACK}x)")
    # heavy_tail is regime-sensitive, not winnable-by-construction: when
    # a drawn giant costs on the order of the ideal makespan, the
    # critical path is that one *indivisible* request, and static's
    # accidental early binding of it beats any amount of node-level
    # rebalancing (no scheduler can split a single request).  So the
    # gate is a band, not a floor: dynamic may trail static by at most
    # the one-giant margin, and a result outside the band in either
    # direction means the simulator or traffic model changed.
    lo, hi = HEAVY_TAIL_BAND
    ht = result["scenarios"]["heavy_tail"]["speedup_vs_static"]
    if not lo <= ht <= hi:
        fails.append(
            f"heavy_tail best-dynamic/static speedup {ht}x left the "
            f"tolerance band [{lo}, {hi}] — either dynamic collapsed "
            f"beyond the indivisible-giant margin or the traffic/cost "
            f"model shifted")
    return fails


def rows(quick: bool = True) -> list[dict]:
    """benchmarks.run entry point."""
    r = run(quick=quick)
    flat = []
    for name, sc in r["scenarios"].items():
        flat.append(dict(name=f"cluster_balance/{name}",
                         static_makespan=sc["static_makespan"],
                         best_dynamic=sc["best_dynamic"],
                         best_dynamic_makespan=sc["best_dynamic_makespan"],
                         speedup_vs_static=sc["speedup_vs_static"],
                         pi_reduced=sc["pi_reduced"]))
    return flat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller request streams (CI)")
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--workers", type=int, default=4,
                    help="decode slots per replica")
    args = ap.parse_args()
    result = run(quick=args.quick, replicas=args.replicas,
                 workers=args.workers)
    RESULTS.mkdir(parents=True, exist_ok=True)
    # --quick (the CI gate) writes its own file so it never dirties the
    # committed full-run artifact
    name = "cluster_balance_quick" if args.quick else "cluster_balance"
    (RESULTS / f"{name}.json").write_text(json.dumps(result, indent=1))
    for name, sc in result["scenarios"].items():
        print(f"{name:17s} static={sc['static_makespan']:>9.4f}  "
              f"best={sc['best_dynamic']:>6s} "
              f"{sc['best_dynamic_makespan']:>9.4f}  "
              f"({sc['speedup_vs_static']:.2f}x, "
              f"pi {'down' if sc['pi_reduced'] else 'up'})")
    fails = check(result)
    if fails:
        raise SystemExit("; ".join(fails))
    print(f"dynamic wins on: {', '.join(result['dynamic_wins'])}; "
          f"uniform static within {result['uniform_static_within']}x")


if __name__ == "__main__":
    main()
