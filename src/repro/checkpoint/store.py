"""Mesh-agnostic sharded checkpointing with async writes and restart.

Format: one directory per step containing
    manifest.json      — tree structure, logical shapes/dtypes, step meta,
                         per-leaf checksums
    <leaf-id>.npy      — full logical arrays (npy, host-gathered)

Arrays are saved in *logical* (unsharded) form, so restore works on ANY
mesh — a pod can die and the job restart at pod=1 (elastic restart path;
exercised in tests/test_checkpoint.py).  At true 1000-node scale the .npy
writes become per-host shard files keyed by (leaf, shard-index) with the
same manifest; the manifest/GC/async machinery here is the real thing.

Features: atomic directory commit (tmp + rename), keep-last-k GC, async
background writer (training continues while the previous step persists),
checksum validation on restore, and `latest_step` discovery for restart.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointStore"]


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(p) for p in path).replace("[", "").replace("]", "")
        key = key.replace("'", "").replace(".", "_").replace("/", "__")
        out.append((key or "root", leaf))
    return out


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None) -> None:
        """Snapshot on the caller thread, persist (optionally) async."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        if self.async_write:
            self._pending = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {}),
                daemon=True)
            self._pending.start()
        else:
            self._write(step, host_tree, extra or {})

    def _write(self, step: int, host_tree, extra: dict) -> None:
        tmp = self.dir / f".tmp-{step}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        treedef = jax.tree_util.tree_structure(host_tree)
        manifest = {"step": step, "extra": extra,
                    "treedef": str(treedef), "leaves": []}
        for i, (key, leaf) in enumerate(_leaf_paths(host_tree)):
            fname = f"{i:04d}_{key[:80]}.npy"
            np.save(tmp / fname, leaf)
            digest = hashlib.sha256((tmp / fname).read_bytes()).hexdigest()[:16]
            manifest["leaves"].append(
                dict(file=fname, key=key, shape=list(np.shape(leaf)),
                     dtype=str(np.asarray(leaf).dtype), sha=digest))
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- load ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like_tree, shardings=None,
                validate: bool = True):
        """Restore into the structure of `like_tree`, resharding to
        `shardings` (pytree of NamedShardings) if given — works on a mesh
        different from the one that saved (elastic restart)."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = []
        for leaf_info in manifest["leaves"]:
            raw = (d / leaf_info["file"]).read_bytes()
            if validate:
                digest = hashlib.sha256(raw).hexdigest()[:16]
                if digest != leaf_info["sha"]:
                    raise IOError(
                        f"checksum mismatch for {leaf_info['file']}")
            arrays.append(np.load(d / leaf_info["file"]))
        treedef = jax.tree_util.tree_structure(like_tree)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            flat_s = treedef.flatten_up_to(shardings)
            flat_a = [jax.device_put(a, s)
                      for a, s in zip(arrays, flat_s)]
            tree = jax.tree_util.tree_unflatten(treedef, flat_a)
        return tree, manifest["extra"]
