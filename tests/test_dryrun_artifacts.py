"""Validate the multi-pod dry-run artifacts (deliverable e + g).

These tests read benchmarks/results/dryrun/*.json produced by
`python -m repro.launch.dryrun --all --both-meshes`.  They are skipped
when the artifacts are absent (e.g. a fresh checkout) — the dry-run
itself needs ~1h of compiles on one CPU core.
"""

import json
from pathlib import Path

import pytest

from repro.configs import ARCHS, SHAPES

RESULTS = Path(__file__).resolve().parents[1] / "benchmarks" / "results" / "dryrun"

pytestmark = pytest.mark.skipif(
    not (RESULTS.exists() and any(RESULTS.glob("*__pod1__baseline.json"))),
    reason="dry-run artifacts not generated",
)


def _load(mesh):
    cells = {}
    for arch in ARCHS:
        for shape in SHAPES:
            f = RESULTS / f"{arch}__{shape}__{mesh}__baseline.json"
            if f.exists():
                cells[(arch, shape)] = json.loads(f.read_text())
    return cells


@pytest.mark.parametrize("mesh", ["pod1", "pod2"])
def test_all_40_cells_present_and_clean(mesh):
    cells = _load(mesh)
    assert len(cells) == 40, f"{mesh}: {len(cells)}/40 cells"
    ok = [k for k, v in cells.items() if v["status"] == "ok"]
    skipped = [k for k, v in cells.items() if v["status"] == "skipped"]
    errors = [k for k, v in cells.items() if v["status"] == "error"]
    assert not errors, errors
    assert len(ok) == 32 and len(skipped) == 8
    # skips are exactly the full-attention long_500k cells
    assert all(k[1] == "long_500k" for k in skipped)
    assert ("xlstm-1.3b", "long_500k") in ok
    assert ("recurrentgemma-2b", "long_500k") in ok


@pytest.mark.parametrize("mesh", ["pod1", "pod2"])
def test_memory_fits_hbm(mesh):
    budget = 16 * 2**30  # v5e HBM
    over = []
    for k, v in _load(mesh).items():
        if v["status"] != "ok":
            continue
        m = v["memory"]
        used = m.get("temp_size_in_bytes", 0) + m.get(
            "argument_size_in_bytes", 0)
        if used > budget:
            over.append((k, used / 2**30))
    assert not over, f"cells over 16GiB: {over}"


def test_pod2_uses_512_chips_and_shards_batch():
    p1 = _load("pod1")
    p2 = _load("pod2")
    for k, v2 in p2.items():
        if v2["status"] != "ok":
            continue
        assert v2["chips"] == 512
        v1 = p1[k]
        if v1["status"] != "ok":
            continue
        # per-device flops at pod2 must not exceed pod1's (batch shards
        # over the pod axis; replicated cells stay equal)
        f1 = v1["cost"].get("flops", 0)
        f2 = v2["cost"].get("flops", 0)
        assert f2 <= f1 * 1.05 + 1e9, (k, f1, f2)


def test_collective_schedule_present():
    for k, v in _load("pod1").items():
        if v["status"] != "ok":
            continue
        assert v["collectives"]["total_wire_bytes"] >= 0
        assert "ops" in v["collectives"]


def test_roofline_analysis_runs():
    import sys
    sys.path.insert(0, str(RESULTS.parents[1].parent))
    from benchmarks.roofline import rows

    table = rows("pod1", "baseline")
    ok_rows = [r for r in table if "dominant" in r]
    assert len(ok_rows) == 32
    assert all(r["dominant"] in ("compute", "memory", "collective")
               for r in ok_rows)
