"""MoE load-balancing demo: the paper's AWF technique as an
auxiliary-loss-free expert balancer (router-bias integral control), plus
the DLS-planned grouped-matmul tile schedule.

    PYTHONPATH=src python examples/moe_balance_demo.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.balance.moe import MoEBalancer, plan_tiles
from repro.configs import ARCHS, smoke_config
from repro.kernels.grouped_matmul.ops import grouped_matmul
from repro.models.moe import _route, init_moe


def main():
    cfg = smoke_config(ARCHS["qwen3-moe-30b-a3b"])
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params, _ = init_moe(jax.random.key(0), cfg)
    e = cfg.moe.num_experts
    route = jax.jit(lambda p, x: _route(p, cfg, x)[3])

    hot = jax.random.normal(jax.random.key(99), (1, 1, cfg.d_model))

    def stream(step):
        base = jax.random.normal(jax.random.fold_in(jax.random.key(1), step),
                                 (4, 64, cfg.d_model))
        return base + 1.5 * hot

    bal = MoEBalancer(num_experts=e, bias_strength=0.05)
    p = dict(params)
    p["router_bias"] = jnp.zeros((e,), jnp.float32)
    print("step  peak/mean load (1.0 = perfectly balanced)")
    for step in range(15):
        load = np.asarray(route(p, stream(step)))
        print(f"{step:4d}  {load.max()/load.mean():.3f}")
        p["router_bias"] = jnp.asarray(bal.update(load), jnp.float32)

    # DLS tile plan for the ragged expert loads -> grouped matmul kernel
    rows = np.asarray(load / load.sum() * 256, dtype=int)
    order = plan_tiles(rows, block_rows=8, p=8)
    xe = jnp.ones((e, max(8, int(np.ceil(rows.max() / 8)) * 8), cfg.d_model),
                  jnp.float32)
    w = jnp.ones((e, cfg.d_model, cfg.moe.d_ff), jnp.float32)
    print(f"\nDLS tile plan: {len(order)} tiles over {e} experts "
          f"(ragged loads {rows.min()}..{rows.max()} rows)")
    out = grouped_matmul(xe, w, block_rows=8, interpret=True)
    print(f"grouped matmul out: {out.shape} (Pallas kernel, interpret mode)")


if __name__ == "__main__":
    main()
