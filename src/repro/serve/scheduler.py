"""Continuous-batching serving scheduler driven by DLS self-scheduling.

The serving queue is the paper's loop: requests are *iterations* with
irregular cost (prompt length + requested tokens), decode slots are
*workers*.  Admission uses the chunk calculus — a freed worker grabs a
DLS-sized chunk of requests instead of one (SS) or a fixed batch
(STATIC); AF/AWF weighting adapts to measured slot throughput, which is
how heterogeneous replicas (or replicas degraded by long contexts) get
less work.

Two layers:
  * `RequestScheduler` — host-side DLS admission over an arrival queue
    (any technique from repro.core; default FAC2).
  * `DecodeEngine` — jit'd batched decode loop over slot states with
    prefill-on-admit; integrates with models.decode_step.

The engine runs on whatever devices exist (CPU harness here, pod mesh in
production); the scheduler's simulated-latency mode drives the serving
benchmark (benchmarks/serving_balance.py).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Union

import numpy as np

from ..core.schedule import ScheduleSpec, resolve

__all__ = ["Request", "RequestScheduler", "simulate_serving"]


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    max_new_tokens: int

    @property
    def cost(self) -> float:
        # prefill ~ quadratic-ish in prompt, decode linear in new tokens
        return 1e-6 * self.prompt_len + 1e-4 * self.max_new_tokens


@dataclasses.dataclass
class RequestScheduler:
    """DLS admission: workers pull chunks of the pending queue.

    ``technique`` accepts a ScheduleSpec or an OMP_SCHEDULE-style string
    (``"runtime"`` / None reads $LB_SCHEDULE, default fac2); an explicit
    ``chunk_param`` argument overrides the spec's.
    """

    num_workers: int
    technique: Union[ScheduleSpec, str, None] = "fac2"
    chunk_param: Optional[int] = None

    def __post_init__(self):
        self.spec = resolve(self.technique, default="fac2",
                            chunk_param=self.chunk_param)
        self._pending: list[Request] = []
        self._tech = None
        self._assigned: dict[int, list[Request]] = {
            w: [] for w in range(self.num_workers)}

    def submit(self, req: Request) -> None:
        self._pending.append(req)

    def pull(self, worker: int) -> list[Request]:
        """A freed worker requests its next chunk of requests."""
        if not self._pending:
            self._tech = None
            return []
        if self._tech is None or self._tech.remaining <= 0:
            self._tech = self.spec.make(
                n=len(self._pending), p=self.num_workers)
            self._cursor = 0
        grant = self._tech.next_chunk(worker)
        if grant is None:
            self._tech = None
            return []
        take = min(grant.size, len(self._pending))
        out = self._pending[:take]
        del self._pending[:take]
        self._assigned[worker].extend(out)
        return out

    @property
    def backlog(self) -> int:
        return len(self._pending)


def simulate_serving(requests: list[Request], num_workers: int,
                     technique: Union[ScheduleSpec, str] = "fac2",
                     chunk_param: Optional[int] = None,
                     worker_speed: Optional[np.ndarray] = None) -> dict:
    """Event-driven serving simulation: returns latency stats.

    Workers process their assigned chunk sequentially (a chunk == one
    continuous batch refill).  Used to reproduce the paper's load-balance
    findings at the serving layer (benchmarks/serving_balance.py).
    """
    sched = RequestScheduler(num_workers=num_workers, technique=technique,
                             chunk_param=chunk_param)
    speed = np.ones(num_workers) if worker_speed is None else worker_speed
    for r in sorted(requests, key=lambda r: r.arrival):
        sched.submit(r)
    free_at = np.zeros(num_workers)
    done: list[tuple[Request, float]] = []
    # all requests pre-arrived (batch regime): workers repeatedly pull
    active = True
    while active:
        active = False
        w = int(np.argmin(free_at))
        chunk = sched.pull(w)
        if chunk:
            active = True
            t = free_at[w]
            for r in chunk:
                t = max(t, r.arrival) + r.cost * speed[w]
                done.append((r, t))
            free_at[w] = t
        elif sched.backlog:
            active = True
    lat = np.array([t - r.arrival for r, t in done])
    return dict(
        n=len(done),
        makespan=float(free_at.max()),
        mean_latency=float(lat.mean()),
        p50=float(np.percentile(lat, 50)),
        p99=float(np.percentile(lat, 99)),
        worker_busy=free_at.tolist(),
        imbalance=float((free_at.max() - free_at.mean())
                        / max(free_at.max(), 1e-9)),
    )
