"""Jit'd public wrapper for the flash-attention Pallas kernels.

`flash_attention` accepts model-layout tensors (b, s, h, hd) with separate
kv-head counts (GQA/MQA) and handles head broadcast, flattening, padding,
and the interpret-mode switch (CPU validation vs TPU execution).

Passing ``schedule=`` routes through the schedule-aware kernel
(`flash_attention_sched_bhsd`): the KV-tile grid order is produced by the
DLS planner instead of the implicit identity order, and ragged per-batch
KV lengths (``kv_lens``) are supported — see
`repro.core.jax_sched.plan_tiles_for_kernel`.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .flash_attention import flash_attention_bhsd, flash_attention_sched_bhsd


def _is_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _broadcast_flatten(q, k, v):
    """(b, s, h|kvh, hd) -> three (b*h, s, hd) lane-major tensors."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    if kvh != h:
        g = h // kvh
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             (b, s, kvh, g, hd)).reshape(b, s, h, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             (b, s, kvh, g, hd)).reshape(b, s, h, hd)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    return flat(q), flat(k), flat(v)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def _flash_attention_dense(q, k, v, *, causal, window, block_q, block_k,
                           interpret):
    b, s, h, hd = q.shape
    qf, kf, vf = _broadcast_flatten(q, k, v)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool | None = None,
                    schedule: Union[str, object, None] = None,
                    kv_lens: Optional[Sequence[int]] = None,
                    sched_p: int = 8, recorder=None):
    """q: (b, s, h, hd); k, v: (b, s, kvh, hd) -> (b, s, h, hd).

    ``schedule`` (a ScheduleSpec / registry name) selects the DLS-planned
    kernel; ``kv_lens`` is a host array of per-batch valid KV lengths
    (ragged decode lanes) — columns past a lane's length are masked.
    ``recorder`` (LoopRecorder) collects the plan's kernel telemetry.
    """
    if interpret is None:
        interpret = not _is_tpu()
    if schedule is None:
        if kv_lens is not None:
            raise ValueError("kv_lens requires schedule= (the DLS-planned "
                             "kernel); the dense grid has no ragged path")
        return _flash_attention_dense(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=interpret)
    b, s, h, hd = q.shape
    qf, kf, vf = _broadcast_flatten(q, k, v)
    lane_lens = None
    if kv_lens is not None:
        lane_lens = np.repeat(np.asarray(kv_lens, np.int64), h)  # per lane
    out = flash_attention_sched_bhsd(
        qf, kf, vf, schedule=schedule, kv_lens=lane_lens, causal=causal,
        window=window, block_q=block_q, block_k=block_k, sched_p=sched_p,
        interpret=interpret, recorder=recorder)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
