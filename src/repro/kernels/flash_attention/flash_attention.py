"""Pallas TPU flash-attention forward kernels.

Two variants share the online-softmax math:

  * `flash_attention_bhsd` — dense (bh, q_blocks, kv_blocks) grid with the
    kv dimension innermost — TPU executes the grid sequentially
    minor-to-major, so the running state (m, l, acc) lives in VMEM scratch
    and is carried across kv steps of one q block.  Causal (and
    sliding-window) masking skips fully-masked kv blocks via pl.when,
    which on real hardware elides both the DMA wait and the MXU work for
    the upper triangle — the half of the quadratic the pure-JAX reference
    (models/attention._attend_flash) cannot avoid under XLA.

  * `flash_attention_sched_bhsd` — the schedule-aware form: a 1-D grid
    over only the *live* (lane, q block, kv block) triples, driven by
    scalar-prefetch descriptor arrays the BlockSpec index maps consume
    (megablox-style).  The q-block group order is produced by the DLS
    planner (`repro.core.jax_sched.plan_tiles_for_kernel`) from per-group
    live-KV costs — causal triangles and ragged per-lane KV lengths give
    q blocks wildly different work, and LB4OMP-style chunked assignment
    makes a contiguous multi-core split of the grid near-balanced, where
    the implicit identity order leaves tail cores idle.  Each group's kv
    steps stay contiguous and ascending (the online-softmax state carries
    in scratch), so outputs are bit-identical for every technique — only
    the group order over the grid changes.

Block shapes are MXU-aligned (multiples of 128 on the contracted dims;
block_q x block_k tiles in VMEM).  VMEM budget per grid step:
    q (bq, hd) + k (bk, hd) + v (bk, hd) + acc (bq, hd) + m/l (bq)
with bq = bk = 512, hd <= 256 in fp32 scratch ~= 1.6 MiB — well inside the
~16 MiB/core VMEM of v5e.

Validated in interpret mode against ref.py (tests/test_kernels.py,
tests/test_kernel_sched.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, seq_len: int, causal: bool,
                  window: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k

    # a kv block is live unless it is entirely above the causal diagonal
    # (or entirely outside the sliding window)
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window > 0:
        live = jnp.logical_and(live,
                               q_start - (k_start + block_k - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < seq_len
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 512, block_k: int = 512,
                         interpret: bool = False):
    """q, k, v: (bh, s, hd) with KV already broadcast to the q-head count.

    Returns (bh, s, hd).  s is padded to the block size internally.
    """
    bh, s, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, max(s, 8))
    block_k = min(block_k, max(s, 8))
    nq = -(-s // block_q)
    nk = -(-s // block_k)
    pad_q = nq * block_q - s
    pad_k = nk * block_k - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=s,
        causal=causal, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pl.MemorySpace.ANY if False else _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s, :]


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - fallback for interpret-only envs
        return pl.MemorySpace.ANY(shape, dtype)  # type: ignore


# ---------------------------------------------------------------------------
# Schedule-aware variant: DLS-planned descriptor grid over live KV tiles
# ---------------------------------------------------------------------------


def _flash_sched_kernel(bi_ref, qi_ref, kj_ref, fst_ref, lst_ref, lim_ref,
                        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                        block_q: int, block_k: int, causal: bool,
                        window: int, scale: float):
    g = pl.program_id(0)

    @pl.when(fst_ref[g] == 1)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi_ref[g] * block_q
    k_start = kj_ref[g] * block_k
    lim = lim_ref[g]                       # this lane's valid KV length

    # every grid step is live by construction (the host planner emitted
    # only (lane, q, kv) triples with work) — no pl.when guard needed
    q = q_ref[0].astype(jnp.float32)       # (bq, hd)
    k = k_ref[0].astype(jnp.float32)       # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale       # (bq, bk)
    rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < lim
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
    m_scr[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(lst_ref[g] == 1)
    def _finalize():
        # rows with every column masked (ragged padding) keep m == NEG_INF;
        # zero them instead of emitting the uniform-softmax garbage
        alive = m_scr[...] > NEG_INF * 0.5
        l = jnp.maximum(l_scr[...], 1e-30)
        out = acc_scr[...] / l[:, None]
        o_ref[0] = jnp.where(alive[:, None], out, 0.0).astype(o_ref.dtype)


def flash_kv_group_costs(bh: int, s: int, block_q: int, block_k: int, *,
                         causal: bool = True, window: int = 0,
                         kv_lens: Optional[np.ndarray] = None):
    """Enumerate the live KV blocks per (lane, q block) group and their
    live-column costs — the cost model of the schedule-aware kernel.

    Returns (group_kjs, costs, lens): per-group ascending kv-block lists,
    the per-group cost array the DLS planner consumes, and the clipped
    per-lane lengths.  Shared by the kernel's descriptor planner and
    `benchmarks/kernel_sched_bench.py` so the published cost model cannot
    drift from what the kernel actually plans.
    """
    nq = -(-s // block_q)
    nk = -(-s // block_k)
    lens = (np.full(bh, s, np.int64) if kv_lens is None
            else np.clip(np.asarray(kv_lens, np.int64), 0, s))
    if lens.shape != (bh,):
        raise ValueError(f"kv_lens must have shape ({bh},), got {lens.shape}")

    group_kjs: list[list[int]] = []
    costs: list[int] = []
    for bi in range(bh):
        lim = int(lens[bi])
        for qi in range(nq):
            q_end = min((qi + 1) * block_q, s) - 1
            kjs = []
            for kj in range(nk):
                k_start = kj * block_k
                if k_start >= lim:
                    break                     # beyond this lane's ragged KV
                if causal and k_start > q_end:
                    break                     # above the causal diagonal
                if window > 0 and (qi * block_q - (k_start + block_k - 1)
                                   >= window):
                    continue                  # below the sliding window
                kjs.append(kj)
            if not kjs:
                # a fully-masked group (padding rows) still needs one step
                # so its output block is initialized and written
                kjs = [0]
            group_kjs.append(kjs)
            # integer block extents: order-exact  # lint: disable=DET004
            costs.append(sum(min(lim, (kj + 1) * block_k) - kj * block_k
                             or block_k for kj in kjs))
    return group_kjs, np.asarray(costs, np.float64), lens


def _plan_kv_descriptors(bh: int, s: int, block_q: int, block_k: int, *,
                         causal: bool, window: int,
                         kv_lens: Optional[np.ndarray], schedule, p: int):
    """Host-side tile planning: enumerate live (lane, q block, kv block)
    triples, DLS-plan the q-block group order, emit descriptor arrays.

    Returns (descriptors, plan): six int32 arrays (bi, qi, kj, first,
    last, lim) of length G = total live triples, plus the KernelTilePlan
    over the (lane, q block) groups.
    """
    from repro.core.jax_sched import plan_tiles_for_kernel

    nq = -(-s // block_q)
    group_kjs, costs, lens = flash_kv_group_costs(
        bh, s, block_q, block_k, causal=causal, window=window,
        kv_lens=kv_lens)
    plan = plan_tiles_for_kernel(costs, p=p, technique=schedule)
    bi_s, qi_s, kj_s, fst_s, lst_s, lim_s = [], [], [], [], [], []
    for gid in plan.order.tolist():
        bi, qi = divmod(gid, nq)
        kjs = group_kjs[gid]
        for j, kj in enumerate(kjs):
            bi_s.append(bi)
            qi_s.append(qi)
            kj_s.append(kj)
            fst_s.append(1 if j == 0 else 0)
            lst_s.append(1 if j == len(kjs) - 1 else 0)
            lim_s.append(int(lens[bi]))
    desc = tuple(np.asarray(a, np.int32)
                 for a in (bi_s, qi_s, kj_s, fst_s, lst_s, lim_s))
    return desc, plan


def flash_attention_sched_bhsd(q, k, v, *,
                               schedule: Union[str, object] = "fac2",
                               kv_lens: Optional[Sequence[int]] = None,
                               causal: bool = True, window: int = 0,
                               block_q: int = 512, block_k: int = 512,
                               sched_p: int = 8, interpret: bool = False,
                               recorder=None, loop_name: str = "flash_kv"):
    """Schedule-aware flash attention: q, k, v (bh, s, hd) -> (bh, s, hd).

    The grid is 1-D over live (lane, q block, kv block) triples only; the
    (lane, q block) group order is DLS-planned from per-group live-KV
    costs via ``plan_tiles_for_kernel`` with ``schedule`` (any registry
    technique / ScheduleSpec).  ``kv_lens`` gives each lane's valid KV
    prefix (ragged sequence lengths, e.g. continuous-batching decode
    lanes); columns at or beyond a lane's length are masked and the dead
    KV blocks never enter the grid.  ``sched_p`` is the number of cores
    the grid is notionally split across (the planner's P).  ``recorder``
    (a ``LoopRecorder``) receives the plan's kernel-level telemetry.

    Output is bit-identical for every ``schedule`` — the technique only
    permutes whole q-block groups; each group's kv steps stay ascending.
    """
    from jax.experimental.pallas import tpu as pltpu

    bh, s, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, max(s, 8))
    block_k = min(block_k, max(s, 8))
    nq = -(-s // block_q)
    nk = -(-s // block_k)
    pad_q = nq * block_q - s
    pad_k = nk * block_k - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    desc, plan = _plan_kv_descriptors(
        bh, s, block_q, block_k, causal=causal, window=window,
        kv_lens=None if kv_lens is None else np.asarray(kv_lens),
        schedule=schedule, p=sched_p)
    if recorder is not None:
        recorder.add(plan.to_record(
            loop_name, instance=recorder.next_instance(loop_name)))
    g = desc[0].shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, block_q, hd),
                         lambda i, bi, qi, kj, fst, lst, lim: (bi[i], qi[i], 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda i, bi, qi, kj, fst, lst, lim: (bi[i], kj[i], 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda i, bi, qi, kj, fst, lst, lim: (bi[i], kj[i], 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, hd),
            lambda i, bi, qi, kj, fst, lst, lim: (bi[i], qi[i], 0)),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _flash_sched_kernel, block_q=block_q, block_k=block_k,
        causal=causal, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, nq * block_q, hd), q.dtype),
        interpret=interpret,
    )(*desc, q, k, v)
    return out[:, :s, :]
