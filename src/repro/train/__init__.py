"""Training steps + production trainer."""

from .steps import make_prefill_step, make_serve_step, make_train_step  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
