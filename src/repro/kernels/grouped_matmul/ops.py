"""Jit'd wrapper: expert-capacity layout (E, C, d) -> DLS-planned tiles ->
grouped matmul -> (E, C, f).

`moe_expert_ffn` is the kernel-backed equivalent of the einsum in
models.moe._expert_ffn's ragged path: the (E, C) capacity buffer is cut
into row tiles of `block_rows`, the tile list is ordered by the DLS
planner (see repro.balance.moe.plan_tiles), and each tile hits the MXU
against its expert's weights.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .grouped_matmul import grouped_matmul_tiles


def _is_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def grouped_matmul(xe, weights, tile_order=None, *, block_rows: int = 128,
                   interpret: bool | None = None):
    """xe: (E, C, d) capacity layout; weights (E, d, f) -> (E, C, f).

    tile_order: optional (T,) permutation of tile ids from the DLS
    planner (T = E * C / block_rows); identity if omitted.
    """
    if interpret is None:
        interpret = not _is_tpu()
    e, c, d = xe.shape
    f = weights.shape[2]
    assert c % block_rows == 0, (c, block_rows)
    tiles_per_e = c // block_rows
    t = e * tiles_per_e
    x_tiles = xe.reshape(t, block_rows, d)
    tile_expert = (jnp.arange(t, dtype=jnp.int32) // tiles_per_e)
    if tile_order is not None:
        x_tiles = x_tiles[tile_order]
        tile_expert = tile_expert[tile_order]
    out = grouped_matmul_tiles(x_tiles, weights, tile_expert,
                               interpret=interpret)
    if tile_order is not None:
        inv = jnp.zeros_like(tile_order).at[tile_order].set(
            jnp.arange(t, dtype=tile_order.dtype))
        out = out[inv]
    return out.reshape(e, c, f)
