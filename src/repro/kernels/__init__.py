"""Pallas TPU kernels (validated interpret=True on CPU).

flash_attention/  BlockSpec-tiled online-softmax attention
grouped_matmul/   DLS-planned expert-tile matmul (megablox-style)
Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit
wrapper), ref.py (pure-jnp oracle).
"""
