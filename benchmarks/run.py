"""Benchmark entry point: one function per paper table/figure plus the
framework benches and the roofline table.  Prints
``name,us_per_call,derived`` CSV rows (and saves JSON under results/).

    PYTHONPATH=src python -m benchmarks.run [--only fig5,roofline] [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (batch_bench, cluster_balance, framework_bench,
               kernel_sched_bench, paper_campaign)
from .common import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--fast", action="store_true",
                    help="smaller Ns for quick runs")
    args = ap.parse_args()

    n_small = 50_000 if args.fast else 200_000
    benches = {
        "fig2_3": lambda: paper_campaign.fig2_fig3(n=n_small),
        "fig5": lambda: paper_campaign.fig5(),
        "fig6": lambda: paper_campaign.fig6(n=n_small),
        "fig7": lambda: paper_campaign.fig7(n=n_small),
        "fig8": lambda: paper_campaign.fig8(n=n_small),
        "fig9_10": lambda: paper_campaign.fig9_10(n=n_small),
        "fig11": lambda: paper_campaign.fig11(
            n=200_000 if args.fast else 1_000_000),
        "moe_balance": framework_bench.moe_balance,
        "auto_select": framework_bench.auto_select,
        "serving": framework_bench.serving,
        "kernels": framework_bench.kernels,
        "packing": framework_bench.packing,
        "batch_speedup": lambda: batch_bench.rows(
            n=n_small, reps=3 if args.fast else 10),
        "kernel_sched": kernel_sched_bench.rows,
        # quick-sized; named so emit() doesn't overwrite the committed
        # full-run cluster_balance.json artifact
        "cluster_balance_quick": cluster_balance.rows,
    }
    # roofline needs dry-run artifacts; include when present
    try:
        from . import roofline

        if roofline.RESULTS.exists() and any(roofline.RESULTS.iterdir()):
            benches["roofline"] = lambda: roofline.rows("pod1", "baseline")
            benches["roofline_pod2"] = lambda: roofline.rows(
                "pod2", "baseline")
    except Exception as e:  # pragma: no cover
        print(f"# roofline unavailable: {e}", file=sys.stderr)

    selected = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    for name in selected:
        if name not in benches:
            print(f"# unknown bench {name}", file=sys.stderr)
            continue
        t0 = time.time()
        rows = benches[name]()
        emit(rows, name)
        print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
