"""Pass registry for repro-lint.

``FILE_PASSES`` run per parsed module; ``PROJECT_PASSES`` run once over
the whole file set.  Adding a pass here is all it takes to wire it into
`python -m tools.lint --check` and the rule catalog.
"""

from .determinism import DeterminismPass
from .trace_safety import TraceSafetyPass
from .robustness import RobustnessPass
from .layering import LayeringPass
from .registry_contract import RegistryContractPass

FILE_PASSES = (
    DeterminismPass(),
    TraceSafetyPass(),
    RobustnessPass(),
)

PROJECT_PASSES = (
    LayeringPass(),
    RegistryContractPass(),
)

__all__ = [
    "FILE_PASSES",
    "PROJECT_PASSES",
    "DeterminismPass",
    "TraceSafetyPass",
    "RobustnessPass",
    "LayeringPass",
    "RegistryContractPass",
]
