"""Synthetic corpus + DLS-packed batching."""

from .pipeline import DataConfig, DataLoader, SyntheticCorpus, pack_documents  # noqa: F401
